"""HLO analysis: trip-count-aware FLOP / byte / collective census + roofline.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts a while
loop's body ONCE, so scan-over-layers models (every model here) under-count
FLOPs and collective traffic by the layer count. This module parses the
post-optimization SPMD HLO, builds the computation call graph (while bodies,
fusions, calls), extracts loop trip counts from the loop conditions, and
accumulates per-device:

* ``flops``   — 2·|result|·K per dot (×4 for complex), × multiplier;
* ``bytes``   — operand+result bytes of every kernel-granularity op (fusion /
  dot / elementwise / data-movement), × multiplier — an HBM-traffic proxy at
  the compiler's fusion granularity;
* per-collective ``count/bytes/traffic`` with ring-factor weighting.

All numbers are per-device (the HLO is the partitioned per-device module).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# per-device traffic factor relative to the result buffer size
_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# Ops counted toward HBM traffic. The CPU backend fuses far less than TPU,
# so counting *every* op would bill VMEM-resident elementwise chains as HBM
# traffic; we count kernel-granularity ops only (matmuls, fusions, data
# movement, reductions) — the TPU model where elementwise work fuses into
# its producer/consumer.
_COUNT_BYTES = {
    "dot", "fusion", "copy", "copy-start", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "reduce", "sort",
    "convolution", "concatenate", "pad", "reverse", "cholesky",
    "triangular-solve", "rng", "reduce-window", "select-and-scatter",
}

_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(t: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(t):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _first_array(t: str) -> Optional[Tuple[str, List[int]]]:
    m = _ARRAY_RE.search(t)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclass
class _Op:
    name: str
    type: str
    opcode: str
    operands: List[str]
    attrs: str


_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)(?:\.clone)?\s*(\([^)]*\))?\s*->.*{\s*$|^(ENTRY\s+)?%?([\w\.\-]+)\s+{\s*$")
_REF_RE = re.compile(r"%([\w\.\-]+)")


def _parse_type_and_op(rest: str) -> Tuple[str, str, str]:
    """rest: everything after '= '. Returns (type, opcode, tail)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rest[: i + 1]
                    tail = rest[i + 1:].strip()
                    break
        else:
            return rest, "", ""
    else:
        sp = rest.find(" ")
        type_str = rest[:sp] if sp > 0 else rest
        tail = rest[sp + 1:].strip() if sp > 0 else ""
    m = re.match(r"([\w\-]+)\(", tail)
    opcode = m.group(1) if m else tail.split("(")[0].strip()
    return type_str, opcode, tail


def parse_hlo(text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            if line.startswith("}"):
                cur = None
                continue
            if "{" in line and ("->" in line or line.rstrip().endswith("{")):
                m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", line)
                if m and not m.group(2).isdigit():
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
            continue
        if cur is None or "=" not in line:
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name = mo.group(1)
        rest = line[mo.end():]
        type_str, opcode, tail = _parse_type_and_op(rest)
        # operands: refs inside the first (...) after opcode; attrs = full tail
        paren = tail.find("(")
        operands: List[str] = []
        if paren >= 0:
            depth = 0
            for i in range(paren, len(tail)):
                if tail[i] == "(":
                    depth += 1
                elif tail[i] == ")":
                    depth -= 1
                    if depth == 0:
                        operands = _REF_RE.findall(tail[paren: i + 1])
                        break
        comps[cur].append(_Op(name, type_str, opcode, operands, tail))
    comps["__entry__"] = comps.get(entry, [])  # type: ignore[arg-type]
    if entry:
        comps["__entry_name__"] = entry  # type: ignore[assignment]
    return comps


def _trip_count(cond_ops: List[_Op]) -> int:
    best = 1
    for op in cond_ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.attrs or "")
            # constants also appear as `constant(28)` inside the op tail
            if m:
                best = max(best, int(m.group(1)))
    # fallback: constants live in the tail we stored in attrs of other ops
    return best


def analyze_hlo(text: str) -> Dict:
    comps = parse_hlo(text)
    entry_name = comps.get("__entry_name__")
    if not isinstance(entry_name, str):
        entry_name = None
    op_lists = {k: v for k, v in comps.items() if isinstance(v, list) and not k.startswith("__")}

    # call-graph edges (caller -> callee, weight = trips for while bodies)
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in op_lists}
    local_t: Dict[str, float] = {c: 1.0 for c in op_lists}  # immediate loop trips
    fusion_internal: Dict[str, bool] = {c: False for c in op_lists}
    for cname, ops in op_lists.items():
        for op in ops:
            attrs = op.attrs or ""
            if op.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", attrs)
                trips = 1
                if mc and mc.group(1) in op_lists:
                    trips = _cond_trips(op_lists[mc.group(1)])
                if mb and mb.group(1) in op_lists:
                    edges[cname].append((mb.group(1), float(trips)))
                    local_t[mb.group(1)] = max(local_t[mb.group(1)], float(trips))
            else:
                for key in ("calls=", "to_apply=", "body=", "condition="):
                    for mm in re.finditer(key + r"%?([\w\.\-]+)", attrs):
                        tgt = mm.group(1)
                        if tgt in op_lists:
                            edges[cname].append((tgt, 1.0))
                            if op.opcode == "fusion" and key == "calls=":
                                fusion_internal[tgt] = True

    # multipliers: sum over call sites, DAG accumulation from the entry
    mult: Dict[str, float] = {c: 0.0 for c in op_lists}
    if entry_name and entry_name in mult:
        mult[entry_name] = 1.0
    indeg: Dict[str, int] = {c: 0 for c in op_lists}
    for c, outs in edges.items():
        for t, _ in outs:
            indeg[t] += 1
    from collections import deque

    q = deque([c for c in op_lists if indeg[c] == 0])
    while q:
        c = q.popleft()
        for t, w in edges[c]:
            mult[t] += mult[c] * w
            indeg[t] -= 1
            if indeg[t] == 0:
                q.append(t)

    # "fused" tier: ops that materialize HBM traffic even under perfect TPU
    # fusion (matmuls, data movement, collectives); the full _COUNT_BYTES set
    # additionally bills fusion-granularity elementwise chains (upper bound).
    _FUSED_TIER = {"dot", "copy", "copy-start", "dynamic-slice",
                   "dynamic-update-slice", "gather", "scatter", "sort",
                   "convolution", "concatenate"}

    flops = 0.0
    byts_upper = 0.0
    byts_fused = 0.0
    colls: Dict[str, Dict[str, float]] = {}
    for cname, ops in op_lists.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        sym = {op.name: op.type for op in ops}
        # Loop-carried buffers (scan xs/ys stacks, remat-saved activations,
        # stacked layer params) are read/written one SLICE per iteration:
        # amortize them by the immediate loop trip count. Carried = reachable
        # from the body's parameter tuple via gte/bitcast/copy chains.
        lt = local_t.get(cname, 1.0)
        carried: set = set()
        if lt > 1:
            for op in ops:
                if op.opcode == "parameter":
                    carried.add(op.name)
                elif op.opcode in ("get-tuple-element", "bitcast", "copy",
                                   "reshape", "transpose") and op.operands \
                        and op.operands[0] in carried:
                    carried.add(op.name)

        def op_bytes(op):
            total = 0.0
            for o in op.operands:
                b = _type_bytes(sym.get(o, ""))
                total += (b / lt) if o in carried else b
            b = _type_bytes(op.type)
            if op.opcode == "dynamic-update-slice" and op.operands \
                    and op.operands[0] in carried:
                b /= lt  # in-place slice write into a carried buffer
            total += b
            return total

        count_bytes_here = not fusion_internal.get(cname, False)
        for op in ops:
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES:
                if op.opcode.endswith("-done"):
                    continue  # avoid double count of start/done pairs
                b = _type_bytes(op.type)
                # The CPU backend canonicalizes bf16 arithmetic to f32, so
                # activation collectives appear at 2x their TPU width; the
                # bf16-corrected tier halves f32 collective payloads (what a
                # bf16-compute model moves on real hardware).
                b16 = b / 2 if ("f32[" in op.type and "bf16" not in op.type) else b
                d = colls.setdefault(base, {"count": 0, "bytes": 0.0,
                                            "traffic": 0.0, "traffic_bf16": 0.0})
                d["count"] += m
                d["bytes"] += b * m
                d["traffic"] += b * _FACTOR[base] * m
                d["traffic_bf16"] += b16 * _FACTOR[base] * m
                byts_upper += b * 2 * m
                byts_fused += b * 2 * m
                continue
            if op.opcode == "dot":
                res = _first_array(op.type)
                lhs_t = sym.get(op.operands[0], "") if op.operands else ""
                lhs = _first_array(lhs_t)
                mm = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.attrs or "")
                k = 1
                if lhs and mm:
                    for d_ in mm.group(1).split(","):
                        if d_:
                            k *= lhs[1][int(d_)] if int(d_) < len(lhs[1]) else 1
                if res:
                    nel = 1
                    for d_ in res[1]:
                        nel *= d_
                    f = 2.0 * nel * k
                    if res[0] in ("c64", "c128"):
                        f *= 4
                    flops += f * m
                if count_bytes_here:
                    b = op_bytes(op)
                    byts_upper += b * m
                    byts_fused += b * m
                continue
            if op.opcode not in _COUNT_BYTES or not count_bytes_here:
                continue
            b = op_bytes(op) * m
            byts_upper += b
            if op.opcode in _FUSED_TIER:
                byts_fused += b

    coll_traffic = sum(d["traffic"] for d in colls.values())
    coll_traffic_bf16 = sum(d["traffic_bf16"] for d in colls.values())
    return {"flops": flops, "bytes": byts_fused, "bytes_upper": byts_upper,
            "collectives": colls, "coll_traffic": coll_traffic,
            "coll_traffic_bf16": coll_traffic_bf16,
            "multipliers": {k: v for k, v in mult.items() if v > 1.5}}


def _cond_trips(cond_ops: List[_Op]) -> int:
    best = 1
    for op in cond_ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.attrs or "")
            if m:
                best = max(best, int(m.group(1)))
        # constants may appear as the full tail 'constant(28)' captured in attrs
        m = re.search(r"constant\((\d+)\)", op.attrs or "")
        if m:
            best = max(best, int(m.group(1)))
    return best


# --------------------------------------------------------------------------
# Roofline
# --------------------------------------------------------------------------


@dataclass
class HardwareSpec:
    """TPU v5e (assignment constants)."""

    peak_flops: float = 197e12  # bf16 / chip
    fp32_flops: float = 49.25e12  # MXU fp32 (complex sim)
    hbm_bw: float = 819e9  # bytes/s / chip
    ici_bw: float = 50e9  # bytes/s/link
    hbm_bytes: float = 16e9


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_detail: Dict[str, Dict[str, float]]
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    hbm_bytes_upper: float = 0.0

    def as_dict(self):
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_upper": self.hbm_bytes_upper,
            "coll_bytes": self.coll_bytes,
            "coll_detail": self.coll_detail,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
        }


def roofline_from_hlo(
    hlo_text: str,
    n_chips: int,
    hw: HardwareSpec = HardwareSpec(),
    model_flops: float = 0.0,
    peak: Optional[float] = None,
) -> Roofline:
    a = analyze_hlo(hlo_text)
    peak = peak or hw.peak_flops
    t_comp = a["flops"] / peak
    t_mem = a["bytes"] / hw.hbm_bw
    t_coll = a.get("coll_traffic_bf16", a["coll_traffic"]) / hw.ici_bw
    dom = max(
        (("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    per_dev_model = model_flops / max(n_chips, 1)
    return Roofline(
        flops=a["flops"],
        hbm_bytes=a["bytes"],
        hbm_bytes_upper=a.get("bytes_upper", a["bytes"]),
        coll_bytes=a.get("coll_traffic_bf16", a["coll_traffic"]),
        coll_detail=a["collectives"],
        t_compute=t_comp,
        t_memory=t_mem,
        t_collective=t_coll,
        dominant=dom,
        model_flops=model_flops,
        useful_ratio=(per_dev_model / a["flops"]) if a["flops"] else 0.0,
    )


def model_flops_train(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) global FLOPs for one train step."""
    n_active = active_params(cfg)
    tokens = shape.seq_len * shape.global_batch
    return 6.0 * n_active * tokens


def model_flops_serve(cfg, shape) -> float:
    n_active = active_params(cfg)
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # one token per sequence


def active_params(cfg) -> float:
    """Parameters touched per token (MoE counts top-k + shared experts)."""
    d = cfg.d_model
    total = 2 * cfg.padded_vocab * d  # embed + head
    kinds = cfg.layer_kinds()
    for kind in kinds:
        if kind.startswith("ssm"):
            d_in = cfg.ssm_expand * d
            nheads = d_in // cfg.ssm_headdim
            total += 2 * d * d_in + 2 * d * cfg.ssm_state + d * nheads + d_in * d
        elif cfg.mla:
            h = cfg.n_heads
            r = cfg.kv_lora_rank
            qdim = h * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
            total += (cfg.q_lora_rank * qdim + d * cfg.q_lora_rank
                      if cfg.q_lora_rank else d * qdim)
            total += d * r + d * cfg.qk_rope_head_dim
            total += r * h * cfg.qk_nope_head_dim + r * h * cfg.v_head_dim
            total += h * cfg.v_head_dim * d
        else:
            hd = cfg.hd
            total += d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
            total += cfg.n_heads * hd * d
        if "+cross" in kind:
            hd = cfg.hd
            total += 2 * (d * cfg.n_heads * hd + d * cfg.n_kv_heads * hd)
        if "+moe" in kind:
            f = cfg.d_ff_expert
            total += 3 * d * f * (cfg.experts_top_k + cfg.n_shared_experts)
        elif cfg.d_ff:  # dense MLP (incl. jamba's non-MoE layers)
            nfac = 3 if cfg.act == "swiglu" else 2
            total += nfac * d * cfg.d_ff
    return float(total)


# kept for backward compatibility with earlier result files
def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    return analyze_hlo(hlo_text)["collectives"]
