import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder devices.

Per cell this records into benchmarks/dryrun_results/<arch>__<shape>__<mesh>.json:
  * memory_analysis()  (proves the program fits / reports per-device bytes)
  * cost_analysis()    (per-device FLOPs & bytes for the roofline)
  * collective census  (bytes per all-gather/all-reduce/reduce-scatter/
                        all-to-all/collective-permute from the SPMD HLO)
  * the derived three-term roofline (see launch/hlo_analysis.py)

Resumable: existing result files are skipped unless --force.
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from ..configs.base import SHAPES, input_specs, shape_applicable
from ..configs.registry import ARCHS, get_arch
from ..optim import adamw
from . import hlo_analysis as ha
from .mesh import make_production_mesh
from .steps import build_model, jitted_serve_step, jitted_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/dryrun_results")


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             opt_overrides: Optional[Dict] = None) -> Dict:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    with mesh:
        # decode steps are latency-bound on tiny per-token tensors: head
        # padding (which shards attention by heads) adds per-layer TP
        # collectives that cost more than the replicated compute they remove —
        # EXPERIMENTS.md §Perf iteration 7. Train/prefill keep padding.
        model = build_model(cfg, mesh, pad_heads=(shape.kind != "decode"))
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig(**(opt_overrides or {}))
            fn, args = jitted_train_step(model, opt_cfg, mesh, shape, multi_pod)
            model_flops = ha.model_flops_train(cfg, shape)
        else:
            fn, args = jitted_serve_step(model, mesh, shape, multi_pod)
            model_flops = ha.model_flops_serve(cfg, shape)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    print(f"[{arch_name} x {shape_name} x {'multi' if multi_pod else 'single'}] "
          f"memory_analysis: {mem}")
    cost = compiled.cost_analysis()
    print(f"  cost_analysis (NOTE: counts while bodies once): "
          f"{ {k: v for k, v in (cost or {}).items() if k in ('flops', 'bytes accessed')} }")
    hlo = compiled.as_text()
    rl = ha.roofline_from_hlo(hlo, n_chips, model_flops=model_flops)

    mem_d = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(mem, attr):
                mem_d[attr] = int(getattr(mem, attr))
    return {
        "status": "ok",
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory_analysis": mem_d or str(mem),
        "cost_flops": float((cost or {}).get("flops", 0.0)),
        "cost_bytes": float((cost or {}).get("bytes accessed", 0.0)),
        "roofline": rl.as_dict(),
    }


def cell_path(results_dir, arch, shape, multi_pod):
    mesh = "multi" if multi_pod else "single"
    return os.path.join(results_dir, f"{arch}__{shape}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--results-dir", default=os.path.normpath(RESULTS_DIR))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.results_dir, exist_ok=True)
    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                path = cell_path(args.results_dir, arch, shape, mp)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            continue
                t0 = time.time()
                try:
                    res = run_cell(arch, shape, mp)
                except Exception as e:  # record failure, keep sweeping
                    res = {"status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                res["wall_s"] = time.time() - t0
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                tag = res["status"].upper()
                if tag == "OK":
                    n_ok += 1
                    dom = res["roofline"]["dominant"]
                    print(f"OK   {arch} {shape} {'multi' if mp else 'single'} "
                          f"({res['wall_s']:.0f}s) dominant={dom}")
                elif tag == "SKIPPED":
                    n_skip += 1
                    print(f"SKIP {arch} {shape}: {res['reason']}")
                else:
                    n_fail += 1
                    print(f"FAIL {arch} {shape} {'multi' if mp else 'single'}: "
                          f"{res['error']}")
    print(f"dry-run done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()
