"""Async multi-tenant **simulation** serving driver (the Atlas engine).

This fronts :class:`repro.serve.SimulationService`: concurrent requests are
grouped by structural CircuitKey and coalesced into single ``run_sweep``
engine calls (flush on max-batch-size or max-wait deadline), behind a
bounded admission queue with per-tenant weighted fairness and a warm
compile-cache pool. It is NOT the transformer decode loop — that lives in
:mod:`repro.launch.serve_llm`.

Demo mode (in-process synthetic traffic, prints the stats snapshot):
  PYTHONPATH=src python -m repro.launch.serve_sim --demo --requests 64 \
      --max-batch 8 --max-wait-ms 5

Server mode (newline-delimited JSON over TCP):
  PYTHONPATH=src python -m repro.launch.serve_sim --port 8765 \
      --max-batch 16 --tenant-weight gold=4 --tenant-weight free=1

Wire protocol (one JSON object per line):
  -> {"id": 1, "tenant": "gold", "family": "su2param", "n": 8,
      "params": {"ry0_0": 0.3, ...} | [0.3, ...],
      "shots": 128, "observables": ["Z0 Z1"], "marginals": [[0, 1]]}
  -> {"id": 2, "circuit_json": "<Circuit.to_json()>"}        (concrete)
  -> {"cmd": "stats"}                                        (snapshot)
  <- {"id": 1, "rid": 1, "ok": true, "amp0": [re, im], "batch_size": 8,
      "counts": {...}, "expectations": {...}, "timings": {...}}
  <- {"id": 9, "rid": 9, "ok": false, "error": "overloaded",
      "message": "...", "retry_after": 0.12}

Error responses are structured: {"rid": <request id or null>, "ok": false,
"error": <stable code: bad_json | bad_request | overloaded | timeout |
quarantined>, "message": <human-readable>}. Malformed input (bad JSON, a
non-object line) gets an error response — it never tears down the
connection. Per-request "timeout" (seconds) sets a deadline; the
--request-timeout flag sets the service-wide default.
"""

from __future__ import annotations

import argparse
import asyncio
import json

import numpy as np

from ..core.circuit import Circuit
from ..core.generators import FAMILIES, PARAM_FAMILIES
from ..sim.faults import FaultError
from ..serve import (
    CircuitQuarantined,
    RequestTimeout,
    ServeConfig,
    ServiceOverloaded,
    SimRequest,
    SimulationService,
)


def _parse_weights(specs):
    out = {}
    for spec in specs:
        if "=" not in spec:
            raise SystemExit(f"--tenant-weight expects NAME=WEIGHT, got {spec!r}")
        name, _, val = spec.partition("=")
        out[name.strip()] = float(val)
    return out


def config_from_args(args) -> ServeConfig:
    return ServeConfig(
        backend=args.backend,
        use_pallas=args.pallas,
        R=args.R,
        G=args.G,
        max_batch_size=args.max_batch,
        max_wait_ms=args.max_wait_ms,
        queue_depth=args.queue_depth,
        tenant_weights=_parse_weights(args.tenant_weight),
        workers=args.workers,
        cache_size=args.cache_size,
        admit_after=args.admit_after,
        request_timeout_s=args.request_timeout,
        verify_norm=not args.no_verify_norm,
    )


def request_from_json(d: dict) -> SimRequest:
    """Build a SimRequest from one wire-protocol object."""
    if "circuit_json" in d:
        circ = Circuit.from_json(d["circuit_json"])
    else:
        fam = d.get("family")
        maker = PARAM_FAMILIES.get(fam) or FAMILIES.get(fam)
        if maker is None:
            raise ValueError(f"unknown family {fam!r}; pick from "
                             f"{sorted(PARAM_FAMILIES) + sorted(FAMILIES)}")
        circ = maker(int(d.get("n", 8)))
    params = d.get("params")
    if isinstance(params, list):
        params = np.asarray(params, dtype=np.float64)
    timeout = d.get("timeout")
    verify = d.get("verify")
    return SimRequest(
        circuit=circ,
        params=params,
        tenant=str(d.get("tenant", "default")),
        shots=int(d.get("shots", 0)),
        marginals=tuple(tuple(m) for m in d.get("marginals", ())),
        observables=tuple(d.get("observables", ())),
        seed=int(d.get("seed", 0)),
        return_state=bool(d.get("return_state", False)),
        L=d.get("L"), R=d.get("R"), G=d.get("G"),
        deadline_s=None if timeout is None else float(timeout),
        verify=None if verify is None else bool(verify),
    )


def error_to_json(rid, error: str, message: str, **extra) -> dict:
    """Structured error shape: every error response carries the request id
    (``rid``, mirrored as ``id`` for older clients), a stable machine-
    readable ``error`` code, and a human-readable ``message``."""
    out = {"id": rid, "rid": rid, "ok": False,
           "error": error, "message": message}
    out.update(extra)
    return out


def response_to_json(rid, resp) -> dict:
    out = {"id": rid, "rid": rid, "ok": True, "batch_size": resp.batch_size,
           "cache_hit": resp.cache_hit, "timings": resp.timings}
    if resp.provenance is not None:
        out["provenance"] = resp.provenance
    if resp.amp0 is not None:
        out["amp0"] = [resp.amp0.real, resp.amp0.imag]
    if resp.state is not None:
        out["state"] = [[float(a.real), float(a.imag)] for a in resp.state]
    if resp.result is not None:
        r = resp.result
        if r.samples is not None:
            out["counts"] = r.counts()
        out["expectations"] = {k: float(v) for k, v in r.expectations.items()}
        out["marginals"] = {",".join(map(str, q)): list(map(float, m))
                            for q, m in r.marginals.items()}
    return out


async def handle_client(svc: SimulationService, reader, writer) -> None:
    async def send(obj):
        writer.write((json.dumps(obj) + "\n").encode())
        await writer.drain()

    async def run_one(rid, d):
        try:
            resp = await svc.submit(request_from_json(d))
            await send(response_to_json(rid, resp))
        except ServiceOverloaded as e:
            await send(error_to_json(rid, "overloaded", str(e),
                                     retry_after=e.retry_after))
        except RequestTimeout as e:
            await send(error_to_json(rid, "timeout", str(e),
                                     deadline_s=e.deadline_s))
        except CircuitQuarantined as e:
            await send(error_to_json(rid, "quarantined", str(e),
                                     retry_after=e.retry_after))
        except Exception as e:  # malformed request, unknown family, ...
            await send(error_to_json(rid, "bad_request",
                                     f"{type(e).__name__}: {e}"))

    tasks = set()
    try:
        while True:
            line = await reader.readline()
            if not line:
                break
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                await send(error_to_json(None, "bad_json", f"bad json: {e}"))
                continue
            if not isinstance(d, dict):
                # a JSON array/scalar line must NOT tear down the connection
                await send(error_to_json(
                    None, "bad_request",
                    f"expected a JSON object, got {type(d).__name__}"))
                continue
            if d.get("cmd") == "stats":
                await send({"ok": True, "stats": svc.stats()})
                continue
            # requests on one connection run concurrently — coalescing
            # needs simultaneous in-flight submissions
            t = asyncio.create_task(run_one(d.get("id"), d))
            tasks.add(t)
            t.add_done_callback(tasks.discard)
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)
    finally:
        writer.close()


async def serve_forever(args) -> None:
    svc = SimulationService(config_from_args(args))
    await svc.start()
    server = await asyncio.start_server(
        lambda r, w: handle_client(svc, r, w), args.host, args.port)
    addrs = ", ".join(str(s.getsockname()) for s in server.sockets)
    print(f"simulation service listening on {addrs} "
          f"(max_batch={args.max_batch}, max_wait={args.max_wait_ms}ms, "
          f"queue={args.queue_depth}, workers={args.workers})", flush=True)
    try:
        async with server:
            await server.serve_forever()
    finally:
        await svc.stop()


async def run_demo(args) -> dict:
    """In-process synthetic traffic: mixed families, mixed tenants, one
    shared stats snapshot printed at the end (returned for tests)."""
    rng = np.random.default_rng(args.seed)
    fams = []
    for spec in args.families.split(","):
        name, _, nq = spec.partition(":")
        sym = PARAM_FAMILIES[name](int(nq or 8))
        fams.append((name, sym, sym.param_names))
    svc = SimulationService(config_from_args(args))
    async with svc:
        async def one(i):
            name, sym, names = fams[i % len(fams)]
            req = SimRequest(
                circuit=sym, tenant=f"tenant{i % 4}",
                params=rng.uniform(0.1, 6.2, len(names)),
                shots=args.shots if i % 7 == 0 else 0,
            )
            try:
                return await svc.submit(req)
            except FaultError as e:  # deadline/quarantine: count, don't crash
                return e

        resps = await asyncio.gather(*[one(i) for i in range(args.requests)])
        stats = svc.stats()
    failed = [r for r in resps if isinstance(r, Exception)]
    resps = [r for r in resps if not isinstance(r, Exception)]
    sizes = [r.batch_size for r in resps] or [0]
    print(f"demo: {len(resps)} responses ({len(failed)} rejected), "
          f"mean batch size {np.mean(sizes):.2f}, coalesce factor "
          f"{stats.get('coalesce_factor', 1.0):.2f}")
    print(json.dumps(stats, indent=2, default=str))
    return stats


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--port", type=int, default=0,
                    help="TCP port for the JSON-lines server (0: demo only)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--demo", action="store_true",
                    help="run in-process synthetic traffic and exit")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--families", default="su2param:8,isingparam:8")
    ap.add_argument("--shots", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    # service knobs
    ap.add_argument("--backend", default="pjit",
                    choices=["pjit", "shardmap", "offload", "dense"])
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--R", type=int, default=0)
    ap.add_argument("--G", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=4.0)
    ap.add_argument("--queue-depth", type=int, default=256)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--cache-size", type=int, default=16)
    ap.add_argument("--admit-after", type=int, default=1)
    ap.add_argument("--tenant-weight", action="append", default=[],
                    metavar="NAME=WEIGHT")
    # robustness knobs
    ap.add_argument("--request-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="default per-request deadline; expired requests get "
                         "a typed timeout error (per-request 'timeout' field "
                         "overrides)")
    ap.add_argument("--no-verify-norm", action="store_true",
                    help="disable the post-run ||psi||=~1 integrity guard")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    if args.demo or not args.port:
        return asyncio.run(run_demo(args))
    return asyncio.run(serve_forever(args))


if __name__ == "__main__":
    main()
