"""Step builders: jitted train / prefill / decode steps with production
shardings. Shared by launch/train.py, launch/serve_llm.py and launch/dryrun.py.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig, input_specs
from ..models.sharding import batch_shardings, cache_shardings, params_shardings
from ..models.transformer import Model
from ..optim import adamw


def data_axes_for(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def pad_heads_for_tp(cfg: ArchConfig, tp: int) -> ArchConfig:
    """Pad the query-head count to a multiple of the TP width so attention
    shards instead of replicating (Megatron-style padding; the extra heads
    are real trainable capacity, ~zero at the roofline when sharded vs the
    16x replication they replace). head_dim is frozen first so padding
    doesn't change it."""
    import dataclasses

    if cfg.n_heads == 0 or tp <= 1 or cfg.mla:
        return cfg
    out = cfg
    if cfg.n_heads % tp != 0:
        padded = ((cfg.n_heads + tp - 1) // tp) * tp
        out = dataclasses.replace(out, head_dim=out.hd, n_heads=padded)
    # fused QKV only when the fused head dim still shards over TP
    if (out.n_heads + 2 * out.n_kv_heads) % tp != 0:
        out = dataclasses.replace(out, qkv_fused=False)
    return out


def build_model(cfg: ArchConfig, mesh: Optional[Mesh], remat: bool = True,
                pad_heads: bool = True) -> Model:
    """``pad_heads=False`` selects the decode parallelism policy: no head
    padding AND no QKV fusion — single-token steps are latency-bound, and
    both transformations add per-layer resharding collectives that cost more
    than the replicated compute they remove (EXPERIMENTS.md §Perf iter. 7)."""
    import dataclasses

    axes = data_axes_for(mesh) if mesh is not None else ("data",)
    if mesh is not None and "model" in mesh.axis_names:
        if pad_heads:
            cfg = pad_heads_for_tp(cfg, mesh.shape["model"])
        elif not cfg.mla and cfg.n_heads:
            cfg = dataclasses.replace(cfg, qkv_fused=False)
    return Model(cfg, mesh=mesh, data_axes=axes, remat=remat)


def make_train_step(
    model: Model,
    opt_cfg: adamw.AdamWConfig,
    microbatches: int = 1,
):
    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, batch
            )
        else:
            def reshape_mb(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            mbs = jax.tree.map(reshape_mb, batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(params, mb)
                g_acc = jax.tree.map(lambda a, b_: a + b_, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(accum, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {"ce_loss": loss}
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        extras = {k: v for k, v in batch.items() if k in ("frames", "patches")}
        logits, cache = model.prefill(params, batch["tokens"], extras=extras or None)
        return logits, cache

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, cache, extras=None):
        logits, cache = model.decode_step(params, tokens, cache, extras=extras)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return decode_step


# ------------------------------------------------------------------ dry-run


def abstract_state(model: Model, opt_cfg: Optional[adamw.AdamWConfig] = None):
    """Abstract params (and optimizer state) via eval_shape — no allocation."""
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_shape = None
    if opt_cfg is not None:
        opt_shape = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), params_shape)
    return params_shape, opt_shape


def jitted_train_step(
    model: Model, opt_cfg: adamw.AdamWConfig, mesh: Mesh,
    shape: ShapeConfig, multi_pod: bool, microbatches: int = 1,
):
    """Returns (jitted fn, (params_shape, opt_shape, batch_shape)) ready to
    ``.lower(...)`` with abstract inputs."""
    params_shape, opt_shape = abstract_state(model, opt_cfg)
    pspec = params_shardings(mesh, params_shape, multi_pod)
    ospec = jax.tree.map(
        lambda s: s, params_shardings(mesh, opt_shape, multi_pod)
    )
    batch_shape = dict(input_specs(model.cfg, shape))
    bspec = batch_shardings(mesh, batch_shape, multi_pod)
    fn = jax.jit(
        make_train_step(model, opt_cfg, microbatches),
        in_shardings=(pspec, ospec, bspec),
        donate_argnums=(0, 1),
    )

    def attach(shapes, specs):
        return jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            shapes, specs,
        )

    args = (attach(params_shape, pspec), attach(opt_shape, ospec),
            attach(batch_shape, bspec))
    return fn, args


def jitted_serve_step(
    model: Model, mesh: Mesh, shape: ShapeConfig, multi_pod: bool,
):
    """Prefill (kind='prefill') or single-token decode (kind='decode')."""
    params_shape, _ = abstract_state(model)
    pspec = params_shardings(mesh, params_shape, multi_pod)
    batch_shape = dict(input_specs(model.cfg, shape))
    bspec = batch_shardings(mesh, batch_shape, multi_pod)

    def attach(shapes, specs):
        return jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            shapes, specs,
        )

    if shape.kind == "prefill":
        fn = jax.jit(make_prefill_step(model), in_shardings=(pspec, bspec))
        return fn, (attach(params_shape, pspec), attach(batch_shape, bspec))

    # decode: cache of length seq_len, one new token
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )
    cspec = cache_shardings(mesh, cache_shape, multi_pod)
    extras_shape = {k: v for k, v in batch_shape.items() if k in ("frames", "patches")}
    espec = {k: bspec[k] for k in extras_shape}

    step = make_decode_step(model)

    if extras_shape:
        fn = jax.jit(
            lambda p, t, c, e: step(p, t, c, e),
            in_shardings=(pspec, bspec["tokens"], cspec, espec),
            donate_argnums=(2,),
        )
        args = (attach(params_shape, pspec), attach({"tokens": batch_shape["tokens"]},
                {"tokens": bspec["tokens"]})["tokens"],
                attach(cache_shape, cspec), attach(extras_shape, espec))
    else:
        fn = jax.jit(
            lambda p, t, c: step(p, t, c),
            in_shardings=(pspec, bspec["tokens"], cspec),
            donate_argnums=(2,),
        )
        args = (attach(params_shape, pspec),
                attach({"tokens": batch_shape["tokens"]},
                       {"tokens": bspec["tokens"]})["tokens"],
                attach(cache_shape, cspec))
    return fn, args
