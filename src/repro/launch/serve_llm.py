"""Batched **LLM** serving driver: transformer prefill + greedy decode loop.

This drives the transformer stack (``repro.models`` / ``repro.configs``) —
it is NOT the quantum-circuit simulation service. For the async multi-tenant
*simulation* service (structure-keyed dynamic batching over the Atlas
engine), see :mod:`repro.launch.serve_sim` and :mod:`repro.serve`.

Example (CPU):
  PYTHONPATH=src python -m repro.launch.serve_llm --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen-len 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_arch
from .mesh import make_host_mesh
from .steps import build_model, make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(data=args.data_par, model=args.model_par)
    model = build_model(cfg, mesh, remat=False)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    B, P, G = args.batch, args.prompt_len, args.gen_len
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size, dtype=jnp.int32)
    extras = None
    if cfg.family == "audio":
        extras = {"frames": jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model),
                                              jnp.bfloat16)}
    if cfg.family == "vlm":
        extras = {"patches": jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model),
                                               jnp.bfloat16)}

    prefill = jax.jit(lambda p, t: model.prefill(p, t, extras=extras,
                                                 cache_len=P + G))
    decode = jax.jit(make_decode_step(model), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]

    out = [tok]
    t0 = time.time()
    for _ in range(G - 1):
        tok, cache = decode(params, tok, cache, extras) if extras else \
            decode(params, tok, cache)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"prefill: {B}x{P} tokens in {t_prefill:.3f}s "
          f"({B*P/max(t_prefill, 1e-9):,.0f} tok/s)")
    print(f"decode: {B}x{G-1} tokens in {t_decode:.3f}s "
          f"({B*(G-1)/max(t_decode, 1e-9):,.0f} tok/s)")
    print("sample generations (token ids):")
    for row in np.asarray(gen)[: min(B, 3)]:
        print("  ", row[:16].tolist())
    return gen


if __name__ == "__main__":
    main()
