"""End-to-end training driver.

Runs on whatever devices exist (CPU smoke to multi-pod TPU): builds the mesh,
the model for ``--arch`` (optionally the reduced smoke config), the synthetic
data pipeline, and a checkpointed, fault-tolerant training loop (auto-resume
from the latest checkpoint, straggler monitor, crash journal).

Example (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 200 --global-batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from ..configs.base import ShapeConfig, input_specs
from ..configs.registry import get_arch
from ..data.synthetic import SyntheticConfig, SyntheticDataset
from ..models.sharding import batch_shardings, params_shardings
from ..optim import adamw
from ..train.checkpoint import CheckpointManager
from ..train.fault_tolerance import RunJournal, StragglerMonitor
from .mesh import make_host_mesh
from .steps import build_model, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data-par", type=int, default=0, help="0 = all devices")
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    ndev = len(jax.devices())
    dp = args.data_par or max(1, ndev // args.model_par)
    mesh = make_host_mesh(data=dp, model=args.model_par)
    model = build_model(cfg, mesh)

    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps
    )
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = adamw.init(opt_cfg, params)

    pspec = params_shardings(mesh, jax.eval_shape(lambda: params))
    ospec = params_shardings(mesh, jax.eval_shape(lambda: opt_state))
    params = jax.device_put(params, pspec)
    opt_state = jax.device_put(opt_state, ospec)

    data = SyntheticDataset(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.global_batch, seed=args.seed,
    ))

    step_fn = jax.jit(
        make_train_step(model, opt_cfg, args.microbatches), donate_argnums=(0, 1)
    )

    start_step = 0
    ckpt = None
    journal = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        journal = RunJournal(os.path.join(args.ckpt_dir, "journal.json"))
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(
                latest, {"params": params, "opt": opt_state},
                {"params": pspec, "opt": ospec},
            )
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            n_restarts = journal.mark_restart()
            print(f"[resume] from step {latest} (restart #{n_restarts})")

    monitor = StragglerMonitor()
    bspec = batch_shardings(mesh, jax.eval_shape(lambda: data.batch(0)))
    history = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = jax.device_put(data.batch(step), bspec)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        if monitor.record(step, dt):
            print(f"[straggler] step {step} took {dt:.3f}s "
                  f"(ewma {monitor.ewma:.3f}s) — flagged")
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics.get('grad_norm', 0)):7.3f} "
                  f"lr {float(metrics.get('lr', 0)):.2e} {dt*1000:6.0f} ms")
            history.append({"step": step, "loss": loss, "dt": dt})
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
            journal.update(step + 1)
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state}, blocking=True)
        journal.update(args.steps)
    total = time.time() - t_start
    tok_s = (args.steps - start_step) * args.global_batch * args.seq / max(total, 1e-9)
    print(f"done: {args.steps - start_step} steps in {total:.1f}s "
          f"({tok_s:,.0f} tok/s); stragglers flagged: {monitor.flagged}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"history": history, "tok_per_s": tok_s,
                       "stragglers": monitor.flagged}, f)
    return history


if __name__ == "__main__":
    main()
