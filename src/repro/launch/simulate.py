"""End-to-end quantum circuit simulation driver (the paper's workload).

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.simulate --circuit qft --n 20 --L 20
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.simulate --circuit qft --n 22 \
      --L 19 --R 2 --G 1 --executor shardmap

Measurement (shots / marginals / Pauli expectations — the result API; no
backend gathers the 2^n probability vector to one device):
  PYTHONPATH=src python -m repro.launch.simulate --circuit qft --n 20 \
      --L 17 --R 3 --executor offload --shots 1024 \
      --marginal 0,1,2 --observable "Z0 Z1 + 0.5*X2"
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..core.generators import FAMILIES
from ..core.partition import partition
from ..sim.statevector import fidelity, simulate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--circuit", default="qft", choices=sorted(FAMILIES))
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--L", type=int, default=0, help="local qubits (0: n-R-G)")
    ap.add_argument("--R", type=int, default=0)
    ap.add_argument("--G", type=int, default=0)
    ap.add_argument("--executor", default="pjit",
                    choices=["pjit", "shardmap", "offload", "pergate"])
    ap.add_argument("--staging", default="ilp", choices=["ilp", "greedy"])
    ap.add_argument("--kernelizer", default="dp", choices=["dp", "ordered", "greedy"])
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--check", action="store_true", help="fidelity vs dense ref")
    ap.add_argument("--shots", type=int, default=0, help="sample N bitstrings")
    ap.add_argument("--seed", type=int, default=0, help="sampling PRNG seed")
    ap.add_argument("--marginal", action="append", default=[],
                    help="comma-separated qubit subset (repeatable)")
    ap.add_argument("--observable", action="append", default=[],
                    help='Pauli sum, e.g. "Z0 Z1 + 0.5*X2" (repeatable)')
    args = ap.parse_args(argv)

    n = args.n
    L = args.L or (n - args.R - args.G)
    circ = FAMILIES[args.circuit](n)
    print(f"{args.circuit}(n={n}): {circ.n_gates} gates; L/R/G = {L}/{args.R}/{args.G}")

    t0 = time.time()
    plan = partition(circ, L, args.R, args.G,
                     staging_method=args.staging, kernelize_method=args.kernelizer)
    print(f"partition: {plan.n_stages} stages, kernel cost {plan.total_kernel_cost:,.0f} us"
          f" (preprocess {plan.preprocess_time_s:.2f}s)")

    measuring = bool(args.shots or args.marginal or args.observable)
    t0 = time.time()
    measurer = None
    if args.executor == "pjit":
        from ..sim.executor import StagedExecutor

        # single-array pjit path; pass a mesh when enough devices exist
        mesh = None
        if args.R + args.G > 0 and len(jax.devices()) >= (1 << (args.R + args.G)):
            rd = 1 << (args.R // 2)
            rm = 1 << (args.R - args.R // 2)
            mesh = jax.make_mesh((1 << args.G, rd, rm), ("pod", "data", "model"))
        ex = StagedExecutor(circ, plan, mesh=mesh)
        out = ex.run_packed() if measuring else ex.run()
    elif args.executor == "shardmap":
        from ..sim.shardmap_executor import ShardMapExecutor

        ex = ShardMapExecutor(circ, plan, use_pallas=args.pallas)
        out = ex.run_packed() if measuring else ex.run()
    elif args.executor == "offload":
        from ..sim.offload import OffloadedExecutor

        ex = OffloadedExecutor(circ, plan)
        out = ex.run(apply_final_remap=not measuring)
    else:
        from ..sim.offload import PerGateOffloadExecutor

        ex = PerGateOffloadExecutor(circ, L)
        out = ex.run()
    if measuring:
        from ..sim.measure import Frame, measurer_for

        # measured runs stay distributed/packed: never gather 2^n amplitudes
        out = jax.block_until_ready(out) if not isinstance(out, np.ndarray) else out
        frame = (ex.measurement_frame if args.executor != "pergate"
                 else Frame.identity(n))
        measurer = measurer_for(out, frame)
    else:
        out = np.asarray(jax.block_until_ready(out)) if not isinstance(out, np.ndarray) else out
    dt = time.time() - t0
    print(f"simulated in {dt:.3f}s ({circ.n_gates / dt:,.0f} gates/s, "
          f"{2**n / dt / 1e6:,.1f} Mamps/s)")

    if measurer is not None:
        from ..sim.measure import measure_to_result

        t0 = time.time()
        res = measure_to_result(
            measurer, backend=args.executor, shots=args.shots, seed=args.seed,
            marginals=[tuple(int(q) for q in spec.split(","))
                       for spec in args.marginal],
            observables=args.observable,
        )
        print(f"measured in {time.time() - t0:.3f}s")
        if args.shots:
            top = ", ".join(f"{b}:{c}" for b, c in res.top(8))
            print(f"  top counts ({args.shots} shots): {top}")
        for qs, m in res.marginals.items():
            head = np.array2string(m[:8], precision=4, suppress_small=True)
            print(f"  marginal{qs}: {head}{' ...' if m.size > 8 else ''}")
        for name, val in res.expectations.items():
            print(f"  <{name}> = {val:+.6f}")
        if not (args.check and n <= 24):
            return res

    if args.check and n <= 24:
        if measurer is not None:
            # measured runs keep the final-stage layout; re-run with the
            # final remap applied for the logical-order fidelity check
            out = ex.run() if args.executor != "pergate" else out
            out = np.asarray(jax.block_until_ready(out)) if not isinstance(out, np.ndarray) else out
        ref = simulate(circ)
        print(f"fidelity vs dense reference: {fidelity(out, ref):.6f}")
    return out


if __name__ == "__main__":
    main()
