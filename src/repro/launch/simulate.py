"""End-to-end quantum circuit simulation driver (the paper's workload).

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.simulate --circuit qft --n 20 --L 20
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.simulate --circuit qft --n 22 \
      --L 19 --R 2 --G 1 --executor shardmap

Measurement (shots / marginals / Pauli expectations — the result API; no
backend gathers the 2^n probability vector to one device):
  PYTHONPATH=src python -m repro.launch.simulate --circuit qft --n 20 \
      --L 17 --R 3 --executor offload --shots 1024 \
      --marginal 0,1,2 --observable "Z0 Z1 + 0.5*X2"

Unified engine (serving path: compile cache + batched initial states):
  PYTHONPATH=src python -m repro.launch.simulate --circuit qft --n 18 \
      --L 15 --R 3 --executor offload --engine --batch 4 --shots 256

Parameterized circuits (structure/parameter split — the compile cache is
structural, so rebinding angles never re-runs ILP/DP/XLA):
  PYTHONPATH=src python -m repro.launch.simulate --circuit isingparam --n 12 \
      --L 10 --R 2 --engine --bind J=0.35 --bind h=0.8 --check
  PYTHONPATH=src python -m repro.launch.simulate --circuit su2param --n 10 \
      --L 10 --sweep points.json --check
(points.json: a JSON list of {name: value} objects, or {"name": [v0, v1, ...]}
columns of equal length.)
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..core.generators import FAMILIES, PARAM_FAMILIES
from ..core.partition import partition
from ..sim.statevector import fidelity, simulate


def _pjit_mesh(R: int, G: int):
    """Build the (pod, data, model) mesh when enough devices exist."""
    if R + G > 0 and len(jax.devices()) >= (1 << (R + G)):
        rd = 1 << (R // 2)
        rm = 1 << (R - R // 2)
        return jax.make_mesh((1 << G, rd, rm), ("pod", "data", "model"))
    return None


def _print_storage_summary(ex):
    snap = (getattr(ex, "provenance", None) or {}).get("storage")
    if snap:
        print(f"storage: {snap['spilled_shards']}/{snap['n_shards']} shards "
              f"at rest on disk after run; {snap['spills']} spills, "
              f"{snap['spill_loads']} reloads; error bound "
              f"{snap['relative_error_bound']:.3e} "
              f"(tol {snap['error_tolerance']})")


def _parse_bind(specs):
    out = {}
    for spec in specs:
        if "=" not in spec:
            raise SystemExit(f"--bind expects name=value, got {spec!r}")
        name, _, val = spec.partition("=")
        out[name.strip()] = float(val)
    return out


def _load_sweep(path):
    """JSON sweep file -> list of {name: value} points."""
    with open(path) as f:
        d = json.load(f)
    if isinstance(d, dict) and "points" in d:
        d = d["points"]
    if isinstance(d, list):
        return [dict(p) for p in d]
    # columns form: {name: [v0, v1, ...]}
    lengths = {len(v) for v in d.values()}
    if len(lengths) != 1:
        raise SystemExit("--sweep columns must have equal length")
    P = lengths.pop()
    return [{k: float(v[p]) for k, v in d.items()} for p in range(P)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--circuit", default="qft",
                    choices=sorted(FAMILIES) + sorted(PARAM_FAMILIES))
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--L", type=int, default=0, help="local qubits (0: n-R-G)")
    ap.add_argument("--R", type=int, default=0)
    ap.add_argument("--G", type=int, default=0)
    ap.add_argument("--executor", default="pjit",
                    choices=["pjit", "shardmap", "offload", "dense", "pergate"])
    ap.add_argument("--staging", default="ilp", choices=["ilp", "greedy"])
    ap.add_argument("--kernelizer", default="dp", choices=["dp", "ordered", "greedy"])
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--opt", dest="opt", action="store_true",
                    help="run the pre-staging circuit optimizer "
                         "(repro.core.optimize: cancel/merge/drop/reorder) "
                         "before planning; --check verifies against the "
                         "UN-optimized dense reference")
    ap.add_argument("--no-opt", dest="opt", action="store_false",
                    help="disable the pre-staging optimizer (default)")
    ap.set_defaults(opt=False)
    ap.add_argument("--autotune", action="store_true",
                    help="A/B-replay candidate plans first and serve the "
                         "fastest (implies --engine; winner is cached)")
    ap.add_argument("--engine", action="store_true",
                    help="route through the unified ExecutionEngine + compile "
                         "cache (repro.sim.engine.engine_for)")
    ap.add_argument("--batch", type=int, default=1,
                    help="run a batch of B basis initial states through the "
                         "engine's fused batch path (implies --engine)")
    ap.add_argument("--check", action="store_true", help="fidelity vs dense ref")
    ap.add_argument("--shots", type=int, default=0, help="sample N bitstrings")
    ap.add_argument("--seed", type=int, default=0, help="sampling PRNG seed")
    ap.add_argument("--marginal", action="append", default=[],
                    help="comma-separated qubit subset (repeatable)")
    ap.add_argument("--observable", action="append", default=[],
                    help='Pauli sum, e.g. "Z0 Z1 + 0.5*X2" (repeatable)')
    ap.add_argument("--storage", default=None, metavar="SPEC",
                    help="tiered at-rest shard store for --executor offload "
                         "(implies --engine): 'exact'|'bf16'|'int8' with "
                         "optional ':dram_kib=N', ':dir=PATH', ':tol=X' — "
                         "e.g. 'int8:dram_kib=4096'. Shards past the DRAM "
                         "budget spill to disk; see README 'Scaling past "
                         "DRAM'")
    ap.add_argument("--dram-budget-mb", type=float, default=None,
                    help="at-rest DRAM budget in MiB for --storage "
                         "(overrides any dram_kib in the spec)")
    ap.add_argument("--spill-dir", default=None,
                    help="directory for spilled shard files (default: the "
                         "system temp dir)")
    ap.add_argument("--storage-tol", type=float, default=None,
                    help="max accumulated quantization error bound before "
                         "the run is rejected (default 0.05)")
    ap.add_argument("--bind", action="append", default=[], metavar="NAME=VAL",
                    help="bind one circuit parameter (repeatable); required "
                         "for parameterized families unless --sweep is given")
    ap.add_argument("--sweep", default=None, metavar="FILE.json",
                    help="run a parameter sweep: every point reuses ONE "
                         "structural compile (implies --engine)")
    ap.add_argument("--vqe", default=None, metavar="OBSERVABLE",
                    help='minimize <H> over the circuit\'s free parameters '
                         'with Adam over adjoint-mode value_and_grad, e.g. '
                         '--vqe "Z0 Z1 + Z1 Z2 + 0.5*X0" (implies --engine)')
    ap.add_argument("--vqe-steps", type=int, default=30)
    ap.add_argument("--vqe-lr", type=float, default=0.1)
    ap.add_argument("--vqe-seed", type=int, default=0)
    args = ap.parse_args(argv)

    n = args.n
    L = args.L or (n - args.R - args.G)
    circ = (FAMILIES.get(args.circuit) or PARAM_FAMILIES[args.circuit])(n)
    print(f"{args.circuit}(n={n}): {circ.n_gates} gates; L/R/G = {L}/{args.R}/{args.G}"
          + (f"; {len(circ.param_names)} free params" if not circ.is_bound else ""))

    measuring = bool(args.shots or args.marginal or args.observable)
    marginals = [tuple(int(q) for q in spec.split(",")) for spec in args.marginal]
    binds = _parse_bind(args.bind)
    storage = None
    if args.storage is not None:
        from ..sim.shard_store import StorageConfig

        if args.executor != "offload":
            ap.error("--storage requires --executor offload")
        storage = StorageConfig.parse(args.storage)
        if storage is not None:
            over = {}
            if args.dram_budget_mb is not None:
                over["dram_bytes"] = int(args.dram_budget_mb * (1 << 20))
            if args.spill_dir is not None:
                over["spill_dir"] = args.spill_dir
            if args.storage_tol is not None:
                over["error_tolerance"] = args.storage_tol
            if over:
                storage = storage.with_overrides(**over)
    use_engine = (args.engine or args.autotune or args.batch > 1
                  or args.executor == "dense" or storage is not None
                  or args.sweep is not None or args.vqe is not None)
    if use_engine and args.executor == "pergate":
        ap.error("--engine/--batch/--sweep do not support the pergate baseline")
    if not use_engine and (binds or not circ.is_bound):
        # legacy executor path: bind eagerly (the engine path binds lazily so
        # the structural compile cache stays parameter-blind)
        circ = circ.bind(binds)
        binds = {}
    # --check always cross-checks against the circuit as the user wrote it,
    # never the optimizer's rewrite of it
    ref_circ = circ

    if use_engine:
        from ..sim.engine import DEFAULT_CACHE, engine_for

        backend_kw = {"mesh": _pjit_mesh(args.R, args.G)} \
            if args.executor == "pjit" else {}
        t0 = time.time()
        if args.autotune:
            from ..core.autotune import autotune_engine

            res = autotune_engine(
                circ, L, args.R, args.G, backend=args.executor,
                use_pallas=args.pallas, backend_kw=backend_kw)
            print(f"autotune: chose '{res.chosen}' "
                  f"({res.speedup_vs_default:.2f}x vs default, "
                  f"{len(res.replay_us)} candidates, "
                  f"{res.tune_time_s:.1f}s"
                  f"{', cached' if res.cached else ''})")
        ex = engine_for(
            circ, L, args.R, args.G, backend=args.executor,
            use_pallas=args.pallas, staging_method=args.staging,
            kernelize_method=args.kernelizer, optimize=args.opt,
            backend_kw=backend_kw, storage=storage,
        )
        plan = ex.plan
        st_cfg = getattr(ex.backend, "storage", None)
        if st_cfg is not None:
            budget = ("unbounded" if st_cfg.dram_bytes is None
                      else f"{st_cfg.dram_bytes / (1 << 20):.1f} MiB")
            print(f"storage: at-rest {st_cfg.at_rest_dtype}, DRAM budget "
                  f"{budget}, tol {st_cfg.error_tolerance}")
        print(f"engine[{ex.backend.name}] ready in {time.time() - t0:.2f}s; "
              f"cache: {len(DEFAULT_CACHE)} entries, {DEFAULT_CACHE.hits} hits"
              f"/{DEFAULT_CACHE.misses} misses")
        opt_prov = getattr(ex, "provenance", {}).get("optimize")
        if opt_prov:
            print(f"optimizer: {opt_prov['gates_before']} -> "
                  f"{opt_prov['gates_after']} gates "
                  f"(-{opt_prov['gates_removed']}; "
                  f"passes: {opt_prov['pass_counts']})")
        if binds:
            t0 = time.time()
            ex.bind(binds)
            print(f"bound {len(binds)} params in {time.time() - t0:.3f}s "
                  "(tensor swap: no ILP/DP/XLA)")
        elif not circ.is_bound and args.sweep is None and args.vqe is None:
            ap.error(f"circuit has free parameters {circ.param_names}; "
                     "pass --bind NAME=VAL, --sweep FILE.json or --vqe OBS")
    else:
        if args.opt:
            from ..core.optimize import optimize_circuit

            ores = optimize_circuit(circ)
            print(f"optimizer: {ores.source.n_gates} -> "
                  f"{ores.circuit.n_gates} gates (-{ores.gates_removed}; "
                  f"passes: {ores.pass_counts()})")
            circ = ores.circuit
        t0 = time.time()
        plan = partition(circ, L, args.R, args.G,
                         staging_method=args.staging,
                         kernelize_method=args.kernelizer)
    print(f"partition: {plan.n_stages} stages, kernel cost {plan.total_kernel_cost:,.0f} us"
          f" (preprocess {plan.preprocess_time_s:.2f}s)")

    # ------------------------------------------------------------ VQE loop
    if args.vqe is not None:
        import jax.numpy as jnp

        from ..core import kernelization, staging
        from ..optim.adamw import AdamWConfig, init as adam_init, \
            update as adam_update

        names = circ.param_names
        if not names:
            ap.error("--vqe needs a parameterized circuit "
                     "(su2param/isingparam or symbolic JSON)")
        rng = np.random.default_rng(args.vqe_seed)
        theta = jnp.asarray(rng.uniform(0.0, 2 * np.pi, len(names)),
                            dtype=jnp.float32)
        cfg = AdamWConfig(lr=args.vqe_lr, weight_decay=0.0, warmup_steps=0,
                          total_steps=max(args.vqe_steps, 1), min_lr_frac=1.0,
                          moment_dtype="float32", clip_norm=10.0)
        opt = adam_init(cfg, theta)
        t0 = time.time()
        value, grads = ex.value_and_grad(args.vqe, params=np.asarray(theta))
        print(f"VQE over {len(names)} params, H = {args.vqe}; first "
              f"value+grad (incl. adjoint trace) in {time.time() - t0:.2f}s")
        solves0 = (staging.SOLVER_CALLS["ilp"], kernelization.SOLVER_CALLS["dp"])
        xla0 = ex.xla_compiles
        t0 = time.time()
        for step in range(args.vqe_steps):
            theta, opt, metrics = adam_update(
                cfg, jnp.asarray(grads, jnp.float32), opt, theta)
            value, grads = ex.value_and_grad(args.vqe, params=np.asarray(theta))
            if step % max(args.vqe_steps // 10, 1) == 0 or step == args.vqe_steps - 1:
                print(f"  step {step:4d}: <H> = {value:+.6f}  "
                      f"|grad| = {float(np.linalg.norm(grads)):.4f}")
        dt = time.time() - t0
        assert (staging.SOLVER_CALLS["ilp"],
                kernelization.SOLVER_CALLS["dp"]) == solves0, \
            "VQE iterations must not re-run ILP/DP"
        assert ex.xla_compiles == xla0, "VQE iterations must not retrace XLA"
        print(f"VQE done: <H> = {value:+.6f} after {args.vqe_steps} steps in "
              f"{dt:.2f}s ({dt / max(args.vqe_steps, 1):.3f}s/step; zero "
              "solver calls, zero retraces)")
        return {"energy": value, "theta": np.asarray(theta),
                "param_names": names}

    # ----------------------------------------------------- parameter sweep
    if args.sweep is not None:
        points = _load_sweep(args.sweep)
        P = len(points)
        t0 = time.time()
        if measuring:
            from ..sim.measure import measure_sweep

            results = measure_sweep(ex, points, shots=args.shots,
                                    seed=args.seed, marginals=marginals,
                                    observables=args.observable)
            dt = time.time() - t0
            print(f"sweep of {P} bindings simulated+measured in {dt:.3f}s "
                  f"({dt / P:.3f}s/point)")
            for p, res in enumerate(results):
                bits = []
                if args.shots:
                    bits.append("top " + ", ".join(
                        f"{s}:{c_}" for s, c_ in res.top(3)))
                bits += [f"<{k}>={v:+.4f}" for k, v in res.expectations.items()]
                print(f"  [{p}] " + "; ".join(bits))
            return results
        out = ex.run_sweep(None, points)
        out = jax.block_until_ready(out) if not isinstance(out, np.ndarray) else out
        dt = time.time() - t0
        print(f"sweep of {P} bindings in {dt:.3f}s ({dt / P:.3f}s/point, "
              f"one structural compile)")
        if args.check and n <= 24:
            for p, pt in enumerate(points):
                ref = simulate(circ.bind(pt))
                print(f"  fidelity[{p}] vs dense reference: "
                      f"{fidelity(np.asarray(out[p]), ref):.6f}")
        return out

    # --------------------------------------------------- batched serving path
    if args.batch > 1:
        B = args.batch
        psi0s = np.zeros((B, 2**n), dtype=np.complex64)
        psi0s[np.arange(B), np.arange(B) % (2**n)] = 1.0
        t0 = time.time()
        if measuring:
            from ..sim.measure import measure_batch

            results = measure_batch(ex, psi0s, shots=args.shots, seed=args.seed,
                                    marginals=marginals,
                                    observables=args.observable)
            dt = time.time() - t0
            print(f"batch of {B} simulated+measured in {dt:.3f}s "
                  f"({dt / B:.3f}s/state)")
            for b, res in enumerate(results):
                bits = []
                if args.shots:
                    bits.append("top " + ", ".join(
                        f"{s}:{c_}" for s, c_ in res.top(3)))
                bits += [f"<{k}>={v:+.4f}" for k, v in res.expectations.items()]
                print(f"  [{b}] " + "; ".join(bits))
            return results
        out = ex.run_batch(psi0s)
        out = jax.block_until_ready(out) if not isinstance(out, np.ndarray) else out
        dt = time.time() - t0
        print(f"batch of {B} simulated in {dt:.3f}s ({dt / B:.3f}s/state, "
              f"{B * circ.n_gates / dt:,.0f} gates/s)")
        _print_storage_summary(ex)
        if args.check and n <= 24:
            for b in range(B):
                f = fidelity(np.asarray(out[b]), simulate(circ, psi0=psi0s[b]))
                print(f"  fidelity[{b}] vs dense reference: {f:.6f}")
        return out

    # ------------------------------------------------------ single-state path
    t0 = time.time()
    measurer = None
    if not use_engine:
        if args.executor == "pjit":
            from ..sim.executor import StagedExecutor

            ex = StagedExecutor(circ, plan, mesh=_pjit_mesh(args.R, args.G))
        elif args.executor == "shardmap":
            from ..sim.shardmap_executor import ShardMapExecutor

            ex = ShardMapExecutor(circ, plan, use_pallas=args.pallas)
        elif args.executor == "offload":
            from ..sim.offload import OffloadedExecutor

            ex = OffloadedExecutor(circ, plan)
        else:
            from ..sim.offload import PerGateOffloadExecutor

            ex = PerGateOffloadExecutor(circ, L)
    if args.executor == "pergate":
        out = ex.run()
    else:
        out = ex.run_packed() if measuring else ex.run()
    if measuring:
        from ..sim.measure import Frame, measurer_for

        # measured runs stay distributed/packed: never gather 2^n amplitudes
        out = jax.block_until_ready(out) if not isinstance(out, np.ndarray) else out
        frame = (ex.measurement_frame if args.executor != "pergate"
                 else Frame.identity(n))
        measurer = measurer_for(out, frame)
    else:
        out = np.asarray(jax.block_until_ready(out)) if not isinstance(out, np.ndarray) else out
    dt = time.time() - t0
    print(f"simulated in {dt:.3f}s ({circ.n_gates / dt:,.0f} gates/s, "
          f"{2**n / dt / 1e6:,.1f} Mamps/s)")
    if use_engine:
        _print_storage_summary(ex)

    if measurer is not None:
        from ..sim.measure import measure_to_result

        t0 = time.time()
        res = measure_to_result(
            measurer, backend=args.executor, shots=args.shots, seed=args.seed,
            marginals=marginals,
            observables=args.observable,
        )
        print(f"measured in {time.time() - t0:.3f}s")
        if args.shots:
            top = ", ".join(f"{b}:{c}" for b, c in res.top(8))
            print(f"  top counts ({args.shots} shots): {top}")
        for qs, m in res.marginals.items():
            head = np.array2string(m[:8], precision=4, suppress_small=True)
            print(f"  marginal{qs}: {head}{' ...' if m.size > 8 else ''}")
        for name, val in res.expectations.items():
            print(f"  <{name}> = {val:+.6f}")
        if not (args.check and n <= 24):
            return res

    if args.check and n <= 24:
        if measurer is not None:
            # measured runs keep the final-stage layout; re-run with the
            # final remap applied for the logical-order fidelity check
            out = ex.run() if args.executor != "pergate" else out
            out = np.asarray(jax.block_until_ready(out)) if not isinstance(out, np.ndarray) else out
        ref = simulate(ref_circ if ref_circ.is_bound else ref_circ.bind(binds))
        print(f"fidelity vs dense reference: {fidelity(out, ref):.6f}")
    return out


if __name__ == "__main__":
    main()
