"""Fault-tolerance utilities: straggler detection + restart bookkeeping.

On a real multi-pod fleet the monitor's flag would trigger hot-spare
substitution / slice reconfiguration; here it feeds the training log and the
fault-tolerance tests (kill-and-resume via CheckpointManager).
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class StragglerMonitor:
    """EWMA step-time monitor. Flags steps slower than ``threshold`` x the
    moving average (collective-synchronized training makes every worker see
    the straggler, so a single-process monitor is representative)."""

    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 3
    ewma: float = 0.0
    n: int = 0
    flagged: List[int] = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            self.ewma = dt if self.ewma == 0.0 else 0.5 * (self.ewma + dt)
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.flagged.append(step)
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


@dataclass
class RunJournal:
    """Crash-safe run journal: records progress so a restarted job can verify
    it resumed from the right step (and count restarts)."""

    path: str

    def read(self) -> Dict:
        if not os.path.exists(self.path):
            return {"restarts": 0, "last_step": -1}
        with open(self.path) as f:
            return json.load(f)

    def _write(self, d: Dict) -> None:
        # tmp + fsync + rename: os.replace alone is NOT crash-safe — after a
        # power loss the rename can survive while the data blocks don't,
        # leaving a truncated/empty journal. fsync the tmp file first so the
        # rename only ever publishes durable bytes.
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(d, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)

    def update(self, step: int, **extra) -> None:
        d = self.read()
        d["last_step"] = step
        d.update(extra)
        self._write(d)

    def mark_restart(self) -> int:
        d = self.read()
        d["restarts"] = d.get("restarts", 0) + 1
        self._write(d)
        return d["restarts"]
