"""Sharded checkpointing: atomic, async-capable, reshard-on-restore.

Format: one ``.npz`` per checkpoint (leaf path -> array) + a JSON manifest.
Restore accepts a different mesh/sharding than save (elastic resharding):
arrays are loaded host-side and ``device_put`` against the new shardings, so
a run checkpointed on N devices resumes on M devices unchanged — this is the
fault-tolerance + elasticity substrate used by launch/train.py.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


# numpy can't serialize ml_dtypes (bfloat16, fp8); round-trip through a raw
# integer view with a dtype tag in the key.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        arr = np.asarray(leaf)
        dt = str(arr.dtype)
        if dt in _EXOTIC:
            arr = arr.view(_EXOTIC[dt])
            key = f"{key}::{dt}"
        flat[key] = arr
    return flat


def _decode_key(key: str, arr: np.ndarray):
    if "::" in key:
        key, dt = key.rsplit("::", 1)
        import ml_dtypes

        arr = arr.view(np.dtype(getattr(ml_dtypes, dt)))
    return key, arr


def _unflatten(tree_like, flat: Dict[str, np.ndarray]):
    leaves_paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)
    out = []
    for path, like in leaves_paths:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        arr = flat[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"checkpoint leaf {key}: shape {arr.shape} != {like.shape}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        flat = _flatten(state)  # device->host copy happens here, synchronously

        def _write():
            tmp = tempfile.mkdtemp(dir=self.dir)
            try:
                npz_path = os.path.join(tmp, "state.npz")
                np.savez(npz_path, **flat)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump({"step": step, "time": time.time(),
                               "n_leaves": len(flat)}, f)
                final = os.path.join(self.dir, f"step_{step:08d}")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)  # atomic publish
            finally:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)
            self._gc()

        self.wait()
        if self.async_save and not blocking:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, "manifest.json")
            ):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        path = os.path.join(self.dir, f"step_{step:08d}", "state.npz")
        flat = {}
        with np.load(path) as z:
            for k in z.files:
                key, arr = _decode_key(k, z[k])
                flat[key] = arr
        state = _unflatten(like, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings
            )
        return state

    def restore_latest(self, like: Any, shardings: Any = None) -> Tuple[Optional[int], Any]:
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, shardings)
