"""Gradient compression for the slow (DCN / inter-pod) axis.

At 1000+ node scale the pod-level gradient sync crosses data-center network,
~10-20x slower than ICI; compressing that hop is the standard lever. This
module provides:

* :func:`quantize_int8` / :func:`dequantize_int8` — per-block symmetric int8
  quantization (block = trailing dim), 4x smaller wires than fp32;
* :class:`ErrorFeedback` — residual accumulation so quantization error is
  re-injected next step (EF-SGD; keeps convergence);
* :func:`compressed_psum` — shard_map-compatible int8 all-reduce over a named
  axis: quantize -> all_gather int8 -> dequantize+sum locally. For g pod
  participants this moves g x int8 instead of 2x fp32 ring traffic — a win
  for small g (pods), not for large ICI groups, which is exactly the DCN
  shape (g = 2..8 pods).

Wiring: for the pjit train step the gradient reduction is fused into
backward by GSPMD, so compression applies when the pod axis is driven
explicitly (shard_map data-parallel outer loop / multi-controller deployment).
`launch/train.py` keeps the uncompressed default; the multi-pod deployment
path uses `compressed_psum` over axis 'pod'.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax


class QuantState(NamedTuple):
    q: jnp.ndarray  # int8 payload
    scale: jnp.ndarray  # per-block fp32 scale


def quantize_int8(x: jnp.ndarray) -> QuantState:
    """Symmetric per-row int8 quantization over the trailing dim."""
    x32 = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return QuantState(q=q, scale=scale)


def dequantize_int8(qs: QuantState, dtype=jnp.float32) -> jnp.ndarray:
    return (qs.q.astype(jnp.float32) * qs.scale).astype(dtype)


class ErrorFeedback(NamedTuple):
    residual: Any  # pytree matching grads

    @staticmethod
    def init(grads) -> "ErrorFeedback":
        return ErrorFeedback(
            residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        )


def compress_with_feedback(
    grads, ef: ErrorFeedback
) -> Tuple[Any, Any, ErrorFeedback]:
    """Returns (quantized pytree, dequantized-for-use pytree, new feedback).

    The residual (what int8 could not represent) is added back before the
    next quantization, so the long-run average is unbiased.
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        qs = quantize_int8(corrected)
        deq = dequantize_int8(qs)
        return qs, deq, corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qs_tree = treedef.unflatten([o[0] for o in out])
    deq_tree = treedef.unflatten([o[1] for o in out])
    new_ef = ErrorFeedback(residual=treedef.unflatten([o[2] for o in out]))
    return qs_tree, deq_tree, new_ef


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8 mean-reduce over a (small, slow) named axis inside shard_map.

    quantize locally -> all_gather int8 payloads -> dequantize and average
    locally. Wire bytes: g x (n/4 + n/blocksize) fp32-equivalents vs
    2 x n fp32 for a ring all-reduce.
    """
    qs = quantize_int8(x)
    qg = lax.all_gather(qs.q, axis_name)  # [g, ...] int8
    sg = lax.all_gather(qs.scale, axis_name)
    g = qg.shape[0]
    deq = qg.astype(jnp.float32) * sg
    return (jnp.sum(deq, axis=0) / g).astype(x.dtype)
